"""Benchmark suite: one entry per paper table/figure (DESIGN.md §6 index).

Each function returns a list of CSV rows ``(name, us_per_call, derived)``:
``us_per_call`` is the headline time of the measured object (or the metric
itself scaled to us where noted), ``derived`` carries the figure-specific
quantity (drift, p-value, invalid fraction, ...).

Simulation-backed figures use the calibrated cluster simulator
(:mod:`repro.core.simnet`); ``real_*`` entries time actual jitted JAX
executables through the same experimental design (the deployment path).

Module knobs, set by ``benchmarks.run`` flags:

  * ``SEED_OFFSET`` (``--seed``): added to every simulator seed so the
    whole suite can be re-rolled under a different RNG universe;
  * ``N_WORKERS`` (``--workers``): campaign launch epochs fan out over a
    process pool (results are bit-identical to the serial run);
  * ``STORE_PATH`` (``--store``): persist every campaign cell to an
    append-only JSONL :class:`~repro.campaign.ResultStore` (resumable).
"""

from __future__ import annotations

import time
import warnings

import numpy as np

from repro.campaign import (Campaign, CampaignSpec, FunctionBackend,
                            ResultStore, SimBackend)
from repro.core import (
    ClockParams,
    ExperimentDesign,
    SimNet,
    TestCase,
    analyze_records,
    autocorr_significant_lags,
    compare_tables,
    jarque_bera,
    make_op,
    make_sync,
    probe_barrier_skew,
    run_barrier_timed,
    run_design,
    run_windowed,
    true_offsets,
    tukey_filter,
    wilcoxon_rank_sum,
)
from repro.core.window import run_windowed_scalar

SYNC_KW = dict(n_fitpts=200, n_exchanges=40)
ALGOS = ("skampi", "netgauge", "jk", "hca", "hca2")

SEED_OFFSET = 0    # set by benchmarks.run --seed
# Campaign launch epochs can fan out over processes (benchmarks.run
# --workers). Serial by default: with the vectorized engine a simulated
# epoch is ~10 ms, far below process-pool startup cost; epoch parallelism
# pays off for heavyweight epochs (large p, real jit-compiled epochs).
N_WORKERS = 1
STORE_PATH = None  # set by benchmarks.run --store


def _seed(s):
    return s + SEED_OFFSET


def _kw(name):
    return SYNC_KW if name in ("jk", "hca", "hca2") else {}


def _campaign(seed0, n=10, nrep=60, msizes=(256, 4096), op_kw=None, p=8):
    """The paper method against the simulator, via the campaign subsystem.

    :class:`~repro.campaign.SimBackend` is a picklable dataclass, so the
    ``N_WORKERS`` epoch fan-out still works. With ``--store`` the campaign
    additionally persists every cell to the JSONL store (and *resumes* —
    re-running the suite against the same store re-measures nothing).
    """
    backend = SimBackend(p=p, seed0=seed0, op_kw=op_kw or {})
    cases = [TestCase("allreduce", m) for m in msizes]
    design = ExperimentDesign(n, nrep, seed=seed0)
    if STORE_PATH:
        if N_WORKERS > 1 and not _campaign.warned_serial:
            _campaign.warned_serial = True
            warnings.warn("--store runs campaigns through the (serial) "
                          "Campaign orchestrator; --workers is ignored",
                          RuntimeWarning, stacklevel=2)
        res = Campaign(CampaignSpec(cases, design, name=f"suite-{seed0}"),
                       backend, ResultStore(STORE_PATH)).run()
        return res.table
    records = run_design(design, backend, cases=cases, n_workers=N_WORKERS)
    return analyze_records(records)


_campaign.warned_serial = False


# --------------------------------------------------------------------- T1
def bench_table1_variability():
    """Table 1: min/max of per-epoch means under the NAIVE method (single
    mpirun per number) vs the paper method's dispersion."""
    rows = []
    for msize in (16, 256, 4096, 32768):
        means = []
        for epoch in range(30):
            net = SimNet(16, seed=_seed(9000 + epoch))
            sync = make_sync("hca", **SYNC_KW).synchronize(net)
            wr = run_windowed(net, sync, make_op("bcast"), msize, 100,
                              win_size=400e-6)
            means.append(np.mean(tukey_filter(wr.valid_times)))
        mn, mx = float(np.min(means)), float(np.max(means))
        rows.append((f"table1/bcast@{msize}", mn * 1e6,
                     f"maxdiff={(mx - mn) / mn * 100:.2f}%"))
    return rows


# --------------------------------------------------------------------- F3
def bench_fig3_clock_drift():
    """Fig. 3: raw clock drift between a reference host and others."""
    net = SimNet(7, seed=_seed(1))
    rows = []
    horizon = 50.0
    net.sleep_all(horizon)
    for r in range(1, 7):
        drift = net.true_offset(r, 0)
        rows.append((f"fig3/host{r}_drift_50s", abs(drift) * 1e6,
                     f"{drift * 1e6:+.1f}us/50s"))
    return rows


# --------------------------------------------------------------------- F5
def bench_fig5_freq_estimation():
    """Figs. 4-5: frequency-estimation error blows up offset-only drift."""
    rows = []
    for label, fe in (("fixed_freq", 0.0), ("estimated_freq", 4.3e-6)):
        offs = []
        for seed in range(5):
            net = SimNet(16, seed=_seed(500 + seed),
                         clocks=ClockParams(skew_sigma=1e-7, freq_est_sigma=fe))
            res = make_sync("netgauge").synchronize(net)
            net.sleep_all(10.0)
            offs.append(np.abs(true_offsets(net, res))[1:].max())
        rows.append((f"fig5/{label}_drift_10s", float(np.median(offs)) * 1e6,
                     f"n={len(offs)}"))
    return rows


# --------------------------------------------------------------------- F6
def bench_fig6_runtime_drift():
    """Fig. 6: windowed run-times drift under offset-only sync; stable under
    drift-corrected sync and under barrier."""
    rows = []
    nrep, bins = 2000, 10
    for name in ("skampi", "hca"):
        net = SimNet(16, seed=_seed(6))
        sync = make_sync(name, **_kw(name)).synchronize(net)
        wr = run_windowed(net, sync, make_op("bcast", autocorr=0.0), 8192,
                          nrep, win_size=300e-6)
        t = wr.times.reshape(bins, -1).mean(axis=1)
        slope = float(np.polyfit(np.arange(bins), t, 1)[0])
        rows.append((f"fig6/{name}_first_bin", t[0] * 1e6,
                     f"slope={slope * 1e6:+.3f}us/bin last={t[-1] * 1e6:.1f}us"))
    net = SimNet(16, seed=_seed(6))
    br = run_barrier_timed(net, make_op("bcast", autocorr=0.0), 8192, nrep)
    t = br.times_local.reshape(bins, -1).mean(axis=1)
    slope = float(np.polyfit(np.arange(bins), t, 1)[0])
    rows.append(("fig6/barrier_first_bin", t[0] * 1e6,
                 f"slope={slope * 1e6:+.3f}us/bin last={t[-1] * 1e6:.1f}us"))
    return rows


# --------------------------------------------------------------------- F8
def bench_fig8_offset_after_sync():
    """Fig. 8: max global-clock offset right after synchronization vs p."""
    rows = []
    for p in (8, 32):
        for name in ALGOS:
            offs = []
            for seed in range(3):
                net = SimNet(p, seed=_seed(800 + seed))
                res = make_sync(name, **_kw(name)).synchronize(net)
                offs.append(np.abs(true_offsets(net, res))[1:].max())
            rows.append((f"fig8/p{p}/{name}", float(np.median(offs)) * 1e6,
                         f"n=3"))
    return rows


# --------------------------------------------------------------------- F9
def bench_fig9_drift_over_time():
    """Fig. 9: offset 0/10/20 s after sync for every algorithm."""
    rows = []
    for name in ALGOS:
        net = SimNet(16, seed=_seed(9))
        res = make_sync(name, **_kw(name)).synchronize(net)
        o0 = np.abs(true_offsets(net, res))[1:].max()
        net.sleep_all(10.0)
        o10 = np.abs(true_offsets(net, res))[1:].max()
        net.sleep_all(10.0)
        o20 = np.abs(true_offsets(net, res))[1:].max()
        rows.append((f"fig9/{name}", o20 * 1e6,
                     f"t0={o0 * 1e6:.2f}us t10={o10 * 1e6:.2f}us"))
    return rows


# -------------------------------------------------------------------- F10
def bench_fig10_pareto():
    """Fig. 10: offset-after-5s vs sync-phase duration Pareto frontier."""
    rows = []
    settings = [("skampi", {}), ("netgauge", {}),
                ("jk", dict(n_fitpts=60, n_exchanges=20)),
                ("jk", dict(n_fitpts=200, n_exchanges=40)),
                ("hca", dict(n_fitpts=60, n_exchanges=20)),
                ("hca", dict(n_fitpts=200, n_exchanges=40)),
                ("hca2", dict(n_fitpts=200, n_exchanges=40))]
    for name, kw in settings:
        net = SimNet(32, seed=_seed(10))
        res = make_sync(name, **kw).synchronize(net)
        net.sleep_all(5.0)
        off = np.abs(true_offsets(net, res))[1:].max()
        tag = f"{name}({kw.get('n_fitpts', '-')},{kw.get('n_exchanges', '-')})"
        rows.append((f"fig10/{tag}", res.duration * 1e6,
                     f"offset5s={off * 1e6:.2f}us msgs={res.n_messages}"))
    # barrier reference line
    net = SimNet(32, seed=_seed(10))
    exits = net.dissemination_barrier()
    rows.append(("fig10/barrier_skew", float(exits.max() - exits.min()) * 1e6,
                 "imbalance reference"))
    return rows


# ---------------------------------------------------------------- F11/F12
def bench_fig11_12_barrier():
    """Figs. 11-12: barrier-based vs window-based measurement; exit skew."""
    op_kw = dict(rank_imbalance=0.01, noise_sigma=0.01, tail_prob=0.0)
    net = SimNet(16, seed=_seed(11))
    sync = make_sync("hca", **SYNC_KW).synchronize(net)
    wr = run_windowed(net, sync, make_op("allreduce", **op_kw), 32768, 300,
                      win_size=500e-6)
    net2 = SimNet(16, seed=_seed(11))
    br = run_barrier_timed(net2, make_op("allreduce", **op_kw), 32768, 300,
                           barrier_exit_skew=40e-6)
    rows = [
        ("fig11/window_global", wr.valid_times.mean() * 1e6, ""),
        ("fig11/barrier_local_max", np.mean(br.times_local) * 1e6,
         "includes exit skew"),
    ]
    net3 = SimNet(16, seed=_seed(12))
    prof = probe_barrier_skew(net3, nrep=300, barrier_exit_skew=40e-6)
    rows.append(("fig12/mvapich_like_skew", prof.mean(axis=0).max() * 1e6,
                 "max mean exit offset"))
    net4 = SimNet(16, seed=_seed(12))
    prof = probe_barrier_skew(net4, nrep=300, use_library_barrier=False)
    rows.append(("fig12/dissemination_skew", prof.mean(axis=0).max() * 1e6,
                 "framework barrier"))
    return rows


# ---------------------------------------------------------------- F14/F15
def bench_fig14_15_distributions():
    """Fig. 14: non-normal, bimodal run-time distributions. Fig. 15: sample
    size for the CLT to hold on sample means."""
    net = SimNet(16, seed=_seed(14))
    sync = make_sync("hca", **SYNC_KW).synchronize(net)
    wr = run_windowed(net, sync, make_op("scan"), 10000, 3000,
                      win_size=500e-6)
    x = wr.valid_times
    jb, p = jarque_bera(x)
    rows = [("fig14/scan_raw_nonnormal", x.mean() * 1e6,
             f"JB={jb:.1f} p={p:.1e} (non-normal expected)")]
    rng = np.random.default_rng(_seed(0))
    for n in (10, 30):
        means = np.array([rng.choice(x, n).mean() for _ in range(2000)])
        jb, p = jarque_bera(means)
        rows.append((f"fig15/mean_sample_n{n}", means.mean() * 1e6,
                     f"JB={jb:.1f} p={p:.1e}"))
    return rows


# ---------------------------------------------------------------- F16/F17
def bench_fig16_17_mpirun_factor():
    """Figs. 16-17: distinct launch epochs produce significantly different
    means; the distribution of epoch means is ~normal."""
    table = _campaign(_seed(1600), n=20, nrep=80, msizes=(8192,),
                      op_kw=dict(epoch_bias_sigma=0.03))
    case = table.cases()[0]
    means = table.means(case)
    spread = (means.max() - means.min()) / means.mean() * 100
    jb, p = jarque_bera(means)
    return [
        ("fig16/epoch_mean_spread", means.mean() * 1e6,
         f"spread={spread:.1f}% over {means.size} epochs"),
        ("fig17/epoch_means_normality", means.std() * 1e6,
         f"JB p={p:.2f} (normal expected)"),
    ]


# -------------------------------------------------------------------- F18
def bench_fig18_autocorrelation():
    """Fig. 18: consecutive measurements are correlated; sub-sampling
    removes the correlation without moving the mean."""
    net = SimNet(16, seed=_seed(18))
    sync = make_sync("hca", **SYNC_KW).synchronize(net)
    wr = run_windowed(net, sync, make_op("bcast", autocorr=0.5), 1000, 2000,
                      win_size=300e-6)
    x = wr.times
    lags = autocorr_significant_lags(x, 20)
    sub = x[:: 10]
    lags_sub = autocorr_significant_lags(sub, 20)
    return [
        ("fig18/raw", x.mean() * 1e6, f"sig_lags={lags.size}"),
        ("fig18/subsampled_10x", sub.mean() * 1e6,
         f"sig_lags={lags_sub.size} dmean={abs(sub.mean() - x.mean()) / x.mean() * 100:.2f}%"),
    ]


# ---------------------------------------------------------------- F21/F22
def bench_fig21_22_window_size():
    """Figs. 21-22: window size vs invalid fraction and run-time stability."""
    rows = []
    for win in (30e-6, 100e-6, 300e-6, 1000e-6):
        net = SimNet(16, seed=_seed(21))
        sync = make_sync("hca", **SYNC_KW).synchronize(net)
        wr = run_windowed(net, sync, make_op("alltoall"), 8192, 400,
                          win_size=win)
        med = float(np.median(wr.valid_times)) * 1e6 if wr.valid_times.size else 0.0
        rows.append((f"fig21/win{int(win * 1e6)}us", med,
                     f"invalid={wr.invalid_fraction * 100:.1f}%"))
    return rows


# ------------------------------------------------------------ F27/F28/F30
def bench_fig27_30_comparison():
    """Figs. 27/28/30: naive single-epoch comparison flips; the Wilcoxon
    comparison on per-epoch medians is stable and directional."""
    lib_a = dict(gamma=2.0e-6)                       # "library A"
    lib_b = dict(gamma=2.0e-6, alpha=3.6e-6)         # "library B": slower alpha
    table_a = _campaign(_seed(2700), n=12, nrep=60, op_kw=lib_a)
    table_b = _campaign(_seed(2900), n=12, nrep=60, op_kw=lib_b)
    rows = []
    # naive: compare epoch-0 means only
    for case in table_a.cases():
        a0 = [s.mean for s in table_a.summaries
              if s.case.key() == case.key() and s.epoch == 0][0]
        b0 = [s.mean for s in table_b.summaries
              if s.case.key() == case.key() and s.epoch == 0][0]
        rows.append((f"fig27/naive@{case.msize}", a0 * 1e6,
                     f"A/B={a0 / b0:.3f} (single epoch — unreliable)"))
    for row in compare_tables(table_a, table_b):
        rows.append((f"fig28/wilcoxon@{row.case.msize}", row.avg_a * 1e6,
                     f"p2={row.p_two_sided:.1e}{row.stars} "
                     f"pA<B={row.p_a_less:.1e} verdict={row.verdict}"))
    return rows


# -------------------------------------------------------------------- F31
def bench_fig31_reproducibility():
    """Fig. 31: dispersion of normalized results across full repetitions —
    naive (1 epoch x default reps) vs the paper method (n epochs)."""
    rows = []
    msize = 1024

    def naive_trial(seed):
        net = SimNet(16, seed=_seed(seed))
        sync = make_sync("skampi").synchronize(net)
        wr = run_windowed(net, sync, make_op("bcast"), msize, 60,
                          win_size=300e-6)
        return float(np.mean(wr.times))

    # naive_trial applies _seed() itself — pass the raw base seed
    naive = np.array([naive_trial(31000 + t) for t in range(6)])
    rows.append(("fig31/naive_dispersion", naive.mean() * 1e6,
                 f"max/min={naive.max() / naive.min():.3f}"))

    trials = []
    for t in range(4):
        table = _campaign(_seed(32000 + 37 * t), n=8, nrep=60, msizes=(msize,))
        trials.append(float(np.mean(table.means(table.cases()[0]))))
    trials = np.array(trials)
    rows.append(("fig31/method_dispersion", trials.mean() * 1e6,
                 f"max/min={trials.max() / trials.min():.3f}"))
    return rows


# ------------------------------------------------------------------ micro
def bench_micro_run_windowed():
    """Engine microbenchmark (not a paper figure): wall-clock of the
    vectorized batch engine vs the scalar reference on the same campaign
    (nrep=10000, p=16), plus the batched-sync speed. The ``derived`` column
    carries the speedup so CI can track the perf trajectory."""
    nrep, p = 10000, 16
    rows = []

    def setup():
        net = SimNet(p, seed=_seed(42))
        sync = make_sync("hca", **SYNC_KW).synchronize(net)
        return net, sync

    t0 = time.perf_counter()
    net, sync = setup()
    t_sync = time.perf_counter() - t0

    timings = {}
    for label, runner in (("scalar", run_windowed_scalar),
                          ("batch", run_windowed)):
        net, sync = setup()
        op = make_op("allreduce")
        t0 = time.perf_counter()
        wr = runner(net, sync, op, 4096, nrep, 300e-6)
        timings[label] = time.perf_counter() - t0
        rows.append((f"micro/run_windowed_{label}",
                     timings[label] / nrep * 1e6,
                     f"wall={timings[label]:.3f}s mean={wr.valid_times.mean() * 1e6:.2f}us "
                     f"invalid={wr.invalid_fraction * 100:.1f}%"))
    rows.append(("micro/run_windowed_speedup",
                 timings["scalar"] / timings["batch"],
                 f"nrep={nrep} p={p} (x, not us)"))
    rows.append(("micro/hca_sync_p16", t_sync * 1e6,
                 f"batched fitpoint sweep, {SYNC_KW}"))
    return rows


def bench_micro_run_windowed_rw():
    """Engine microbenchmark (not a paper figure): the *random-walk* window
    engine — batched drift-path inversion (``engine="batch_rw"``) vs the
    scalar reference on the same campaign with ``rw_sigma > 0``
    (nrep=10000, p=16). Before the batched engine, ``engine="auto"``
    silently dropped every random-walk campaign onto the scalar path."""
    nrep, p = 10000, 16
    rows = []

    def setup():
        net = SimNet(p, seed=_seed(43), clocks=ClockParams(rw_sigma=1e-7))
        sync = make_sync("hca", **SYNC_KW).synchronize(net)
        return net, sync

    timings = {}
    for label in ("scalar", "batch_rw"):
        net, sync = setup()
        op = make_op("allreduce")
        t0 = time.perf_counter()
        wr = run_windowed(net, sync, op, 4096, nrep, 300e-6, engine=label)
        timings[label] = time.perf_counter() - t0
        rows.append((f"micro/run_windowed_rw_{label}",
                     timings[label] / nrep * 1e6,
                     f"wall={timings[label]:.3f}s mean={wr.valid_times.mean() * 1e6:.2f}us "
                     f"invalid={wr.invalid_fraction * 100:.1f}%"))
    rows.append(("micro/run_windowed_rw_speedup",
                 timings["scalar"] / timings["batch_rw"],
                 f"nrep={nrep} p={p} rw_sigma=1e-7 (x, not us)"))
    return rows


def bench_micro_simjax():
    """Engine microbenchmark (not a paper figure): the jit-compiled JAX
    window engine vs the vectorized numpy engine on one large campaign
    (nrep=100000, p=64). Both walls include everything a campaign pays per
    measure call (clock/sync coefficient extraction, RNG, transfers); jit
    compilation is amortized by an untimed warm-up campaign, matching how
    a multi-cell campaign reuses the compiled programs. The speedup row
    (jax must beat numpy) is the CI gate for the accelerator port."""
    from repro.simjax import have_jax

    nrep, p, msize = 100000, 64, 4096
    sync_kw = dict(n_fitpts=60, n_exchanges=20)

    def setup(seed):
        net = SimNet(p, seed=_seed(seed))
        sync = make_sync("hca", **sync_kw).synchronize(net)
        return net, sync

    if not have_jax():
        return [("micro/simjax_unavailable", 0.0, "jax not importable")]

    op = make_op("allreduce")
    for warm_seed in (901, 902):         # compile + first-dispatch warm-up
        net, sync = setup(warm_seed)
        run_windowed(net, sync, op, msize, nrep, 400e-6, engine="jax")

    rows = []
    timings = {}
    for label, engine in (("numpy", "batch"), ("jax", "jax")):
        walls = []
        for trial in range(3):
            net, sync = setup(900 + 10 * trial)
            op = make_op("allreduce")
            t0 = time.perf_counter()
            wr = run_windowed(net, sync, op, msize, nrep, 400e-6,
                              engine=engine)
            walls.append(time.perf_counter() - t0)
        timings[label] = min(walls)
        rows.append((f"micro/simjax_{label}",
                     timings[label] / nrep * 1e6,
                     f"wall={timings[label]:.3f}s (best of 3) "
                     f"mean={wr.times.mean() * 1e6:.2f}us"))
    rows.append(("micro/simjax_speedup",
                 timings["numpy"] / timings["jax"],
                 f"nrep={nrep} p={p} (x, not us; >1 required)"))
    return rows


def bench_micro_fused_campaign():
    """Campaign-resident execution vs per-cell-epoch dispatch (not a paper
    figure): E launch epochs of one case at the simjax-gate shape
    (nrep=100000, p=64), measured as the PR 7 loop of per-epoch jit
    dispatches vs one `run_windowed_epochs_jax` fused call (vmapped
    sampling, chunked-scan window, one trace per shape bucket). Both walls
    pay full campaign-per-epoch overhead (clock/sync extraction, host RNG,
    transfers); compilation is amortized by untimed warm-ups, matching a
    multi-cell campaign. The speedup row is the CI gate for the fused
    engine."""
    from repro.simjax import have_jax, run_windowed_epochs_jax

    if not have_jax():
        return [("micro/fused_campaign_unavailable", 0.0,
                 "jax not importable")]

    E, nrep, p, msize = 4, 100000, 64, 4096
    sync_kw = dict(n_fitpts=60, n_exchanges=20)

    def setup(seed):
        nets, syncs, ops = [], [], []
        for e in range(E):
            net = SimNet(p, seed=_seed(seed) + 1000 * e)
            syncs.append(make_sync("hca", **sync_kw).synchronize(net))
            nets.append(net)
            ops.append(make_op("allreduce"))
        return nets, syncs, ops

    for warm_seed in (901, 902):         # compile + first-dispatch warm-up
        nets, syncs, ops = setup(warm_seed)
        run_windowed(nets[0], syncs[0], ops[0], msize, nrep, 400e-6,
                     engine="jax")
        run_windowed_epochs_jax(nets, syncs, ops, msize, nrep, 400e-6)

    rows = []
    timings = {}
    for label in ("percell", "fused"):
        walls = []
        for trial in range(3):
            nets, syncs, ops = setup(900 + 10 * trial)
            t0 = time.perf_counter()
            if label == "fused":
                run_windowed_epochs_jax(nets, syncs, ops, msize, nrep,
                                        400e-6)
            else:
                for e in range(E):
                    run_windowed(nets[e], syncs[e], ops[e], msize, nrep,
                                 400e-6, engine="jax")
            walls.append(time.perf_counter() - t0)
        timings[label] = min(walls)
        rows.append((f"micro/fused_campaign_{label}",
                     timings[label] / (E * nrep) * 1e6,
                     f"wall={timings[label]:.3f}s (best of 3) "
                     f"E={E} epochs"))
    rows.append(("micro/fused_campaign_speedup",
                 timings["percell"] / timings["fused"],
                 f"E={E} nrep={nrep} p={p} (x, not us; >=3 required)"))
    return rows


def bench_micro_sweeps():
    """Scheduler microbenchmark (not a paper figure): wall-clock of a
    4-cell factor sweep (grid compile + per-cell campaigns + factor-impact
    analysis), so the CI perf gate covers the sweep subsystem. The
    ``derived`` column carries the top-ranked factor as a correctness
    canary: it must be the injected ``tuning`` axis.

    The second row gates the budgeted-allocation subsystem: the same grid
    run under the racing policy, reported as uniform-nrep / spent-nrep.
    The ratio is a pure count of repetitions (machine-independent), so
    check_regression treats it like a speedup row."""
    import os
    import tempfile

    from repro.campaign import SweepScheduler
    from repro.sweeps import (cells_from_result, default_sim_sweep,
                              main_effects, make_policy)

    spec, backend = default_sim_sweep(seed=_seed(7), axes=("tuning", "dtype"),
                                      n_launch_epochs=4, nrep=30)
    t0 = time.perf_counter()
    res = SweepScheduler(spec, backend).run()
    effects = main_effects(cells_from_result(res))
    wall = time.perf_counter() - t0
    top = effects[0]
    rows = [(
        "micro/sweep_4cells",
        wall / len(res.cells) * 1e6,
        f"wall={wall:.3f}s top={top.axis}(|d|={top.effect_size:.2f})",
    )]

    # budgeted allocation on a 6-epoch variant of the same grid (racing
    # needs epoch headroom to halt early; the ratio is exact, not timed)
    spec_a, backend_a = default_sim_sweep(seed=_seed(7),
                                          axes=("tuning", "dtype"),
                                          n_launch_epochs=6, nrep=30)
    with tempfile.TemporaryDirectory() as td:
        store = ResultStore(os.path.join(td, "alloc.jsonl"))
        res_a = SweepScheduler(spec_a, backend_a, store,
                               policy=make_policy("racing")).run()
    alloc = res_a.meta["alloc"]
    decided = ",".join(f"{a}={v}" for a, v in sorted(
        alloc["decisions"].items()))
    rows.append((
        "micro/alloc_savings_speedup",
        float(alloc["savings"]),
        f"rounds={alloc['n_rounds']} spent={alloc['spent_nrep']} "
        f"uniform={alloc['uniform_nrep']} {decided} (x, not us; "
        "racing must beat uniform)",
    ))
    return rows


# ------------------------------------------------------------------- real
def bench_real_step_functions():
    """The deployment path: real jitted JAX executables timed with the full
    method (launch epochs = fresh jit caches) and compared with Wilcoxon.

    Object under test: a smoke-config train_step at two remat settings —
    a genuine performance question answered statistically on this host.
    """
    import jax

    from repro.configs import get_smoke
    from repro.core.runtime_meter import MeterConfig, make_jax_measure
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro.optim import init_opt_state

    cfg = get_smoke("gemma2-2b")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    def build(remat):
        def _build(epoch):
            state = {"params": params, "opt": init_opt_state(params)}
            step = jax.jit(make_train_step(cfg, remat=remat))

            def call():
                return step(state, batch)[1]["loss"]

            return {"train_step": call}
        return _build

    rows = []
    tables = {}
    for label, remat in (("remat", True), ("noremat", False)):
        epoch_factory, measure = make_jax_measure(
            build(remat), MeterConfig(warmup=2))
        records = run_design(ExperimentDesign(4, 15, seed=1),
                             FunctionBackend(epoch_factory, measure,
                                             name=f"jax-{label}"),
                             cases=[TestCase("train_step", 0)])
        tables[label] = analyze_records(records)
        med = tables[label].medians(tables[label].cases()[0])
        rows.append((f"real/train_step_{label}", float(np.mean(med)) * 1e6,
                     f"epochs={med.size}"))
    a = tables["remat"].medians(tables["remat"].cases()[0])
    b = tables["noremat"].medians(tables["noremat"].cases()[0])
    res = wilcoxon_rank_sum(a, b)
    rows.append(("real/remat_vs_noremat", float(np.mean(a)) * 1e6,
                 f"p2={res.p_value:.2e}{res.stars}"))
    return rows


ALL_BENCHES = [
    bench_table1_variability,
    bench_fig3_clock_drift,
    bench_fig5_freq_estimation,
    bench_fig6_runtime_drift,
    bench_fig8_offset_after_sync,
    bench_fig9_drift_over_time,
    bench_fig10_pareto,
    bench_fig11_12_barrier,
    bench_fig14_15_distributions,
    bench_fig16_17_mpirun_factor,
    bench_fig18_autocorrelation,
    bench_fig21_22_window_size,
    bench_fig27_30_comparison,
    bench_fig31_reproducibility,
    bench_micro_run_windowed,
    bench_micro_run_windowed_rw,
    bench_micro_simjax,
    bench_micro_fused_campaign,
    bench_micro_sweeps,
    bench_real_step_functions,
]
