"""Fair comparison of two REAL implementations, the paper's way (§6).

Question: is the Pallas flash-attention kernel faster than its jnp
reference on this host at seq 128/256? Answer it properly: the *same*
campaign spec runs against two :class:`~repro.campaign.KernelBackend`
configurations (``impl="pallas"`` vs ``impl="ref"``), with launch epochs =
fresh jit caches, adaptive nrep, Tukey filtering, and Wilcoxon on
per-epoch medians — not a single-number eyeball.

Off-TPU the Pallas kernel runs in interpret mode, so "ref faster" is the
expected verdict there; on a TPU the same script answers the real
question.

    PYTHONPATH=src python examples/compare_impls.py
"""

from repro.campaign import Campaign, CampaignSpec, KernelBackend
from repro.core import (ExperimentDesign, TestCase, compare_tables,
                        format_comparison)

SEQS = (128, 256)


def main():
    spec = CampaignSpec(
        cases=[TestCase("flash_attention", s) for s in SEQS],
        design=ExperimentDesign(n_launch_epochs=5, nrep_min=5, nrep_max=30,
                                rel_ci_target=0.05, seed=7),
        name="flash-attn-vs-ref",
    )
    shape = dict(batch=2, heads=4, kv_heads=2, head_dim=64)
    pallas = Campaign(spec, KernelBackend(impl="pallas", **shape)).run()
    ref = Campaign(spec, KernelBackend(impl="ref", **shape)).run()

    rows = compare_tables(pallas.table, ref.table)
    print(format_comparison(rows, "pallas", "ref"))
    for r in rows:
        verdict = ("faster than" if r.verdict == "A<B" else
                   "slower than" if r.verdict == "A>B" else
                   "indistinguishable from")
        print(f"verdict @ seq {r.case.msize}: pallas kernel is {verdict} "
              f"the jnp reference (p_less={r.p_a_less:.2e}, "
              f"p_greater={r.p_a_greater:.2e})")


if __name__ == "__main__":
    main()
