"""Fair comparison of two REAL implementations, the paper's way (§6).

Question: is the q-chunked reference attention faster than the dense
reference attention on this host, for a gemma2-style block at seq 1024?
Answer it properly: n launch epochs (fresh jit caches) x nrep fenced
timings, Tukey filtering, Wilcoxon on per-epoch medians, significance
stars — not a single-number eyeball.

    PYTHONPATH=src python examples/compare_impls.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ExperimentDesign, TestCase, analyze_records,
                        compare_tables, format_comparison, run_design)
from repro.core.runtime_meter import MeterConfig, make_jax_measure
from repro.models.attention import _attention_dense, attention_reference

B, S, H, HKV, D = 2, 1024, 8, 2, 64


def make_inputs():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, HKV, D)), jnp.float32)
    return q, k, v


def campaign(fn, name):
    q, k, v = make_inputs()

    def build(epoch):
        f = jax.jit(fn)

        def call():
            return f(q, k, v)

        return {name: call}

    epoch_factory, measure = make_jax_measure(build, MeterConfig(warmup=2))
    recs = run_design(ExperimentDesign(n_launch_epochs=5, nrep=20, seed=7),
                      epoch_factory, measure, [TestCase(name, S)])
    return analyze_records(recs)


def main():
    dense = campaign(
        lambda q, k, v: _attention_dense(q, k, v, causal=True, window=None,
                                         logit_cap=0.0, q_offset=0,
                                         kv_len=None), "attn")
    chunked = campaign(lambda q, k, v: attention_reference(q, k, v), "attn")
    rows = compare_tables(chunked, dense)
    print(format_comparison(rows, "chunked", "dense"))
    for r in rows:
        print(f"\nverdict @ seq {S}: chunked is "
              f"{'faster' if r.verdict == 'A<B' else 'slower' if r.verdict == 'A>B' else 'indistinguishable from'}"
              f" dense (p_less={r.p_a_less:.2e}, p_greater={r.p_a_greater:.2e})")


if __name__ == "__main__":
    main()
