"""Reproducibility-audit walkthrough: certifying a re-run.

The paper's headline claim is *reproducible* measurement — but a
difference test can only ever fail to refute sameness. This script shows
the audit layer doing the stronger thing: archiving a reference run,
re-measuring, and positively certifying EQUIVALENT within a ±10% margin
(TOST on per-epoch medians, Holm across the cell family, bootstrap CIs
on the median ratio) — then catching a seeded drift and showing that a
killed audit resumes from its cell log.

    PYTHONPATH=src python examples/repro_audit.py
"""

import tempfile
from pathlib import Path

from repro.campaign import Campaign, CampaignSpec, ResultStore, SimBackend
from repro.core import ExperimentDesign, TestCase
from repro.history import (RunArchive, audit_runs, format_audit_report,
                           format_drift)

root = Path(tempfile.mkdtemp())
archive = RunArchive(root / "archive")

CASES = [TestCase(op, m) for op in ("allreduce", "bcast", "alltoall")
         for m in (512, 4096)]
DESIGN = ExperimentDesign(n_launch_epochs=12, nrep=40, seed=0)
SYNC = dict(n_fitpts=60, n_exchanges=20)


def measure_and_register(tag=None, per_op_kw=None):
    backend = SimBackend(p=8, seed0=0, per_op_kw=per_op_kw or {},
                         sync_kw=dict(SYNC))
    store = ResultStore(archive.new_store_path())
    Campaign(CampaignSpec(CASES, DESIGN, name="repro-audit"),
             backend, store).run()
    return archive.register(store.path, tag=tag)


# --- 1. measure and archive the reference ---------------------------------
ref = measure_and_register(tag="reference")
print(f"archived reference: run {ref.run_id} "
      f"({ref.n_records} records, host {ref.host})")

# --- 2. re-run and certify ------------------------------------------------
# The archive resolves the baseline (latest earlier run with the same
# factor fingerprint); every cell must come out EQUIVALENT.
cand = measure_and_register()
report = audit_runs(archive, cand)
print()
print(format_audit_report(report, title="same-seed re-run vs reference"))
assert report.all_equivalent

# --- 3. a drifted collective is caught ------------------------------------
# Mis-tune bcast (4x latency term): the audit flags exactly its cells.
bad = measure_and_register(per_op_kw={"bcast": dict(alpha=12e-6, gamma=6e-6)})
drifted = audit_runs(archive, bad, baseline_tag="reference")
print()
print(format_audit_report(drifted, title="mis-tuned bcast vs reference"))
print()
print(format_drift(drifted))
assert {c.op for c in drifted.drifted()} == {"bcast"}

# --- 4. a killed audit resumes from its cell log --------------------------
# Truncate audits.jsonl to two finished cells, as a kill mid-comparison
# would leave it; the re-run recomputes only the missing cells.
log = archive.root / "audits.jsonl"
lines = log.read_text().splitlines()
cells = [i for i, ln in enumerate(lines) if '"audit-cell"' in ln]
log.write_text("\n".join(lines[:cells[1] + 1]) + "\n")
resumed = audit_runs(archive, cand)
print(f"\nkilled after 2 cells -> resume: {resumed.n_resumed} cells loaded, "
      f"{resumed.n_computed} recomputed "
      f"(verdicts unchanged: "
      f"{[c.verdict for c in resumed.cells] == [c.verdict for c in report.cells]})")
