"""End-to-end training driver: fault-tolerant, instrumented, resumable.

Trains an LM on the deterministic synthetic pipeline with async
checkpointing, straggler monitoring and (optionally) an injected failure —
the supervisor restarts from the latest checkpoint and the loss trajectory
provably matches an uninterrupted run.

    PYTHONPATH=src python examples/train_lm.py --steps 120 --preset small
    PYTHONPATH=src python examples/train_lm.py --steps 300 --preset 100m \
        --batch 8 --seq 512           # ~100M params (slow on CPU; sized for
                                      # a single TPU host as-is)
    PYTHONPATH=src python examples/train_lm.py --steps 60 --fail-at 25
"""

import argparse

import numpy as np

from repro.checkpoint.store import CheckpointConfig
from repro.core.stats import mean_confidence_interval, tukey_filter
from repro.data.pipeline import DataConfig
from repro.models import ModelConfig
from repro.optim import OptimizerConfig
from repro.runtime.trainer import (FailureInjector, Trainer, TrainerConfig,
                                   run_supervised)

PRESETS = {
    "tiny": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                 vocab_size=512),
    "small": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                  vocab_size=4096),          # ~5M params
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab_size=32768),  # ~110M params
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step (restart drill)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.preset}", family="dense",
                      dtype="float32", **PRESETS[args.preset])
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    trainer = Trainer(
        cfg, data,
        opt_cfg=OptimizerConfig(lr=args.lr, warmup_steps=20,
                                decay_steps=args.steps),
        trainer_cfg=TrainerConfig(total_steps=args.steps, save_every=20,
                                  log_every=10),
        ckpt_cfg=CheckpointConfig(directory=args.ckpt_dir, keep=2))

    failure = FailureInjector((args.fail_at,)) if args.fail_at else None
    out = run_supervised(trainer, failure)

    losses = out["losses"]
    kept = tukey_filter(np.array(trainer.step_times[5:]))
    m, lo, hi = mean_confidence_interval(kept)
    print(f"\ndone: {out['final_step']} steps, restarts={out['restarts']}")
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f}")
    print(f"step time (Tukey-filtered): {m*1e3:.1f}ms "
          f"[{lo*1e3:.1f}, {hi*1e3:.1f}] 95% CI")
    if out["stragglers"]:
        print(f"straggling steps flagged: {out['stragglers']}")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
