"""Sim↔real calibration walkthrough: fitting the simulator, then
certifying the fit.

A simulator predicts real MPI behavior only when its *variability* model
is calibrated against measurements (Cornebize & Legrand). This script
plays that loop with a simulated "truth" standing in for hardware so it
runs anywhere in seconds: measure the truth, fit SimNet's noise knobs by
deterministic quantile matching, certify the fitted simulator EQUIVALENT
on held-out launch epochs the fit never saw (TOST ±10%, Holm-corrected),
and show that a killed fit resumes by replaying its persisted
``calib-round`` search state. Against real hardware, swap the truth for
``JaxBackend()`` — same call, jax op names (``psum``, ``all_gather``).

    PYTHONPATH=src python examples/calibrate_sim.py
"""

import json
import tempfile
from pathlib import Path

from repro.calibrate import calibrate, default_space
from repro.campaign import ResultStore, SimBackend
from repro.core import ExperimentDesign, TestCase
from repro.history import RunArchive, format_audit_report

root = Path(tempfile.mkdtemp())
archive = RunArchive(root / "archive")

CASES = [TestCase(op, m) for op in ("allreduce", "bcast")
         for m in (512, 4096)]
DESIGN = ExperimentDesign(n_launch_epochs=24, nrep=30, seed=3)
SYNC = dict(n_fitpts=60, n_exchanges=20)

# --- 1. the "truth": what hardware would be -------------------------------
# A simulator with a deliberately shifted latency term and its own seed0
# (the fit must match the *distribution*, not one noise realization).
TRUTH_ALPHA = 6e-6
truth = SimBackend(p=8, seed0=1009, op_kw=dict(alpha=TRUTH_ALPHA),
                   sync_kw=dict(SYNC))

# --- 2. fit a bounded noise-model surface ---------------------------------
# default_space() carries the full phenomenology (AR(1), bimodal tail,
# spikes, imbalance, clock drift); one strongly identifiable knob keeps
# the walkthrough fast.
space = default_space(base=SimBackend(p=8, seed0=0, sync_kw=dict(SYNC)),
                      names=["op.alpha"])
store = ResultStore(archive.new_store_path(stem="calib"))
result = calibrate(space, truth, cases=CASES, design=DESIGN,
                   store=store, archive=archive, seed=3)

fitted = result.params["op.alpha"]
print(f"truth alpha = {TRUTH_ALPHA:.3e}, fitted = {fitted:.3e} "
      f"({abs(fitted - TRUTH_ALPHA) / TRUTH_ALPHA:.1%} off), "
      f"objective {result.objective:.4f} after {len(result.rounds)} rounds")
print()
print(format_audit_report(result.report,
                          title="held-out certification (fit never saw "
                                "these epochs)"))
assert result.ok, result.verdict
print(f"\narchived as run {result.run_entry.run_id} "
      f"[{result.run_entry.tag}]; fit report kinds in the manifest: "
      f"{len(archive.calibrations())}")

# --- 3. a killed fit resumes ----------------------------------------------
# Truncate the store right after the first persisted search round — the
# moment a SIGKILL might land — and run the identical calibrate() again.
lines = store.path.read_text().splitlines(keepends=True)
cut = next(i for i, ln in enumerate(lines)
           if json.loads(ln).get("kind") == "calib-round") + 1
killed = root / "killed.jsonl"
killed.write_text("".join(lines[:cut]))
resumed = calibrate(space, truth, cases=CASES, design=DESIGN,
                    store=ResultStore(killed), seed=3)
assert resumed.params == result.params
assert resumed.n_rounds_resumed == 1
print(f"\nresumed fit: {resumed.n_rounds_resumed} round replayed from the "
      f"store, identical params {resumed.params} — "
      f"verdict {resumed.verdict}")
