"""Factor-impact walkthrough: finding the factor that matters.

The paper's headline contribution is showing *which experimental factors
have an impact on run-time*. This script makes that executable: a factor
grid over a simulated library with one deliberately mis-tuned collective
(the ``tuning`` axis) plus real measurement-mechanical factors and a
known null factor (``dtype`` — a pure label in the simulator). The
nonparametric main-effect analysis must rank the injected defect first,
Holm-significant, and leave the dtype label at the bottom — the positive
and negative control of the whole pipeline.

    PYTHONPATH=src python examples/factor_impact.py
"""

import os
import tempfile

from repro.campaign import ResultStore, SweepScheduler
from repro.sweeps import (cells_from_result, cells_from_store,
                          default_sim_sweep, format_factor_report,
                          interaction_screen, main_effects)

# --- 1. the factor grid ----------------------------------------------------
# Each axis is one Table-4 factor made enumerable: a name, its levels, and
# the backend/design constructor field the levels are applied to. The
# default sweep crosses the injected `tuning` defect with a sync-algorithm
# choice, the window size, and the dtype label — 16 cells.
spec, backend = default_sim_sweep(seed=0, n_launch_epochs=10)
for ax in spec.grid.axes:
    print(f"  {ax.name:<14} ({ax.target}.{ax.kwarg()}): "
          f"{' | '.join(ax.label(i) for i in range(len(ax.levels)))}")
print(f"  -> {spec.grid.n_full()} cells x {len(spec.cases)} cases x "
      f"{spec.design.n_launch_epochs} launch epochs")

# --- 2. run the sweep through a persistent store ---------------------------
# Every cell is an ordinary campaign keyed by its own factor fingerprint;
# the sweep manifest + per-cell completion markers make a killed sweep
# resume at cell granularity.
store_path = os.path.join(tempfile.mkdtemp(), "sweep.jsonl")
result = SweepScheduler(spec, backend, ResultStore(store_path)).run()
print(f"\nmeasured {result.n_cells_measured} cells "
      f"(sweep id {result.sweep_id})")

# --- 3. the "factors that matter" table ------------------------------------
cells = cells_from_result(result)
effects = main_effects(cells)
print()
print(format_factor_report(effects, interaction_screen(cells)))

top = effects[0]
assert top.axis == "tuning" and top.significant, \
    "the injected defect must be the top-ranked, Holm-significant factor"
assert not [e for e in effects if e.axis == "dtype"][0].significant, \
    "the dtype label must stay a null factor"
print("\ncontrols hold: injected factor ranked first, dtype null")

# --- 4. resume: a second run measures nothing ------------------------------
again = SweepScheduler(spec, backend, ResultStore(store_path)).run()
print(f"resume: {again.n_cells_resumed} cells resumed, "
      f"{again.n_cells_measured} measured")

# the persisted sweep reloads without the in-memory result object
effects2 = main_effects(cells_from_store(ResultStore(store_path)))
print(f"store round-trip: top factor {effects2[0].axis!r} "
      f"(|delta|={effects2[0].effect_size:.3f})")
