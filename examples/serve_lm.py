"""Serving driver: batched prefill + decode with measured token latency.

Loads a reduced config, prefi­lls a batch of prompts, decodes N tokens per
request, and reports per-token latency with the paper's statistics (Tukey
filter + CI) — the serve-side analogue of the train driver.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b --tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_smoke
from repro.core.stats import mean_confidence_interval, tukey_filter
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    t0 = time.perf_counter()
    logits, cache = prefill(cfg, params, prompts,
                            max_len=args.prompt_len + args.tokens + 1)
    logits = jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.1f}ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")

    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    lat = []
    generated = [tok]
    for i in range(args.tokens):
        t0 = time.perf_counter()
        logits, cache = step(params, cache, tok)
        tok = jax.block_until_ready(jnp.argmax(logits, axis=-1))
        lat.append(time.perf_counter() - t0)
        generated.append(tok)

    lat = np.array(lat[2:])  # drop compile steps
    kept = tukey_filter(lat)
    m, lo, hi = mean_confidence_interval(kept)
    print(f"decode: {args.tokens} steps x {args.batch} seqs")
    print(f"per-step latency (Tukey-filtered): {m*1e3:.2f}ms "
          f"[{lo*1e3:.2f}, {hi*1e3:.2f}] 95% CI "
          f"-> {args.batch/m:.0f} tok/s")
    out = jnp.concatenate(generated, axis=1)
    assert out.shape == (args.batch, args.tokens + 1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("sample token ids:", np.asarray(out[0, :12]))


if __name__ == "__main__":
    main()
