"""Guideline verification walkthrough: auditing a collectives library.

PGMPI-style performance guidelines (arXiv:1606.00215) are self-consistency
requirements — "allgather must not lose to alltoall", "bcast must not lose
to a scatter+allgather mock-up of itself" — and the paper's measurement
method exists precisely so such claims get defensible verdicts. This
script verifies the stock guideline family against an honest simulated
library, then against one with a deliberately mis-tuned collective, and
shows the resumable store in between.

    PYTHONPATH=src python examples/verify_guidelines.py
"""

import os
import tempfile

from repro.campaign import ResultStore, SimBackend
from repro.core import ExperimentDesign
from repro.guidelines import (SIM_GUIDELINES, Guideline, format_report,
                              format_violations, verify_guidelines)

design = ExperimentDesign(n_launch_epochs=10, nrep_min=20, nrep_max=120,
                          rel_ci_target=0.05, seed=0)

# --- 1. the guideline family ----------------------------------------------
# Each guideline is `lhs ⪯ rhs` over op expressions: "+" sequences
# collectives inside one timed region (a mock-up), "*k" scales the message
# size, "@half" runs a term on half the processes (split-robustness).
for g in SIM_GUIDELINES:
    print(f"  {g.name:<30} {g.lhs} ⪯ {g.rhs}"
          + (f"  (rhs at {g.rhs_msize_scale:g}x msize)"
             if g.rhs_msize_scale != 1.0 else ""))

# --- 2. verify against an honest library, through a persistent store ------
store_path = os.path.join(tempfile.mkdtemp(), "guidelines.jsonl")
honest = SimBackend(p=8, seed0=0)
report = verify_guidelines(SIM_GUIDELINES, honest, design=design,
                           store=ResultStore(store_path))
print()
print(format_report(report, title="honest library"))

# --- 3. re-running resumes: every cell loads, nothing is re-measured ------
report2 = verify_guidelines(SIM_GUIDELINES, honest, design=design,
                            store=ResultStore(store_path))
print(f"\nresume: measured={report2.n_measured} "
      f"resumed={report2.n_resumed} (same verdicts: "
      f"{[v.verdict for v in report2.verdicts] == [v.verdict for v in report.verdicts]})")

# --- 4. a mis-tuned collective is flagged ---------------------------------
# Inflate alltoall's latency terms; the mock-up bound that holds for the
# honest model is now broken, and only it. per_op_kw is part of the factor
# fingerprint, so this campaign cannot silently resume the honest one.
family = list(SIM_GUIDELINES) + [
    Guideline("alltoall_mock_bound", lhs="alltoall",
              rhs="allreduce*2+bcast*2",
              description="mock-up bound: alltoall ⪯ allreduce(2m)+bcast(2m)"),
]
seeded = SimBackend(p=8, seed0=0,
                    per_op_kw={"alltoall": dict(alpha=12e-6, gamma=10e-6)})
bad = verify_guidelines(family, seeded, design=design)
print()
print(format_report(bad, title="mis-tuned alltoall"))
print()
print(format_violations(bad) or "no violations")
