"""Cross-pod gradient compression with error feedback (distributed-opt trick).

At 1000+ nodes the pod-to-pod (DCN) hop is the scarcest bandwidth: this
demo simulates the cross-pod gradient reduction of a 2-pod mesh with int8
blockwise quantization + error feedback, and shows (a) ~4x wire-volume
reduction, (b) training-equivalent accumulated updates (the error-feedback
residual stays bounded, so Adam sees an unbiased gradient stream), and
(c) the decision made the paper's way — Wilcoxon on per-epoch loss
trajectories of compressed vs uncompressed runs.

    PYTHONPATH=src python examples/compressed_dp.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wilcoxon_rank_sum
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import ModelConfig, init_params
from repro.optim import OptimizerConfig, adamw_update, init_opt_state
from repro.optim.compress import error_feedback_update

CFG = ModelConfig(name="dp-demo", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
                  dtype="float32")
OPT = OptimizerConfig(lr=2e-3, warmup_steps=5, weight_decay=0.0)
PODS = 2
STEPS = 20


@jax.jit
def grads_of(params, batch):
    from repro.models import loss_fn

    def lf(p):
        loss, _ = loss_fn(CFG, p, batch)
        return loss

    return jax.value_and_grad(lf)(params)


def run(compressed: bool, seed: int):
    params = init_params(CFG, jax.random.PRNGKey(seed))
    opt = init_opt_state(params)
    residuals = [None] * PODS
    sources = [SyntheticLM(DataConfig(vocab_size=CFG.vocab_size, seq_len=64,
                                      global_batch=4, seed=100 + p))
               for p in range(PODS)]
    losses, wire_bytes = [], 0
    for step in range(STEPS):
        pod_grads, pod_losses = [], []
        for p in range(PODS):
            batch = {k: jnp.asarray(v) for k, v in
                     sources[p].batch_at(step).items()}
            loss, g = grads_of(params, batch)
            pod_losses.append(float(loss))
            if compressed:
                comp, decomp, residuals[p] = error_feedback_update(
                    g, residuals[p])
                wire_bytes += sum(q.size + s.size * 4
                                  for q, s in jax.tree_util.tree_leaves(
                                      comp, is_leaf=lambda x: isinstance(x, tuple)))
                pod_grads.append(decomp)          # what crosses the DCN
            else:
                wire_bytes += sum(4 * l.size for l in
                                  jax.tree_util.tree_leaves(g))
                pod_grads.append(g)
        # cross-pod mean (the DCN all-reduce)
        mean_g = jax.tree.map(lambda *gs: sum(gs) / PODS, *pod_grads)
        params, opt, _ = adamw_update(params, mean_g, opt, OPT)
        losses.append(float(np.mean(pod_losses)))
    return np.array(losses), wire_bytes


def main():
    base_losses, base_bytes = run(False, seed=0)
    comp_losses, comp_bytes = run(True, seed=0)
    print(f"wire volume: fp32 {base_bytes/2**20:.1f} MiB -> "
          f"int8+ef {comp_bytes/2**20:.1f} MiB "
          f"({base_bytes/comp_bytes:.2f}x reduction)")
    print(f"final loss: fp32 {base_losses[-1]:.4f} vs "
          f"compressed {comp_losses[-1]:.4f}")
    res = wilcoxon_rank_sum(base_losses[-8:], comp_losses[-8:])
    print(f"Wilcoxon on last-10 losses: p={res.p_value:.3f}{res.stars or ' '}"
          f" -> {'indistinguishable' if res.p_value > 0.05 else 'different'}")
    assert comp_losses[-1] < comp_losses[0]
    assert base_bytes / comp_bytes > 3.0


if __name__ == "__main__":
    main()
