"""Quickstart: the paper's methodology in 60 lines.

Synchronize a (simulated) 16-host cluster with HCA, measure a collective
under window-based sync vs. a skewed library barrier, then compare two
"MPI libraries" the statistically sound way (Wilcoxon on per-epoch medians).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    ExperimentDesign, SimNet, TestCase, analyze_records, compare_tables,
    format_comparison, make_op, make_sync, run_barrier_timed, run_design,
    run_windowed, true_offsets,
)

# --- 1. drift-corrected clock synchronization (HCA, §4.4) -----------------
net = SimNet(16, seed=0)
sync = make_sync("hca", n_fitpts=200, n_exchanges=40).synchronize(net)
print(f"HCA sync: {sync.duration:.3f}s, "
      f"max offset {np.abs(true_offsets(net, sync))[1:].max()*1e6:.2f}us")
net.sleep_all(10.0)
print(f"  after 10s of drift: "
      f"{np.abs(true_offsets(net, sync))[1:].max()*1e6:.2f}us (still synced)")

# --- 2. window-based vs barrier-based measurement (§4.6) -------------------
op = make_op("allreduce")
wr = run_windowed(net, sync, op, msize=8192, nrep=200, win_size=400e-6)
net2 = SimNet(16, seed=0)
br = run_barrier_timed(net2, op, 8192, 200, barrier_exit_skew=40e-6)
print(f"windowed global time : {wr.valid_times.mean()*1e6:8.2f}us "
      f"(invalid {wr.invalid_fraction*100:.1f}%)")
print(f"barrier local-max    : {br.times_local.mean()*1e6:8.2f}us "
      f"(includes ~40us library barrier skew!)")

# --- 3. statistically sound comparison (§6) --------------------------------
def campaign(op_kw, seed0):
    def epoch(e):
        n = SimNet(8, seed=seed0 + 997 * e)
        s = make_sync("hca", n_fitpts=200, n_exchanges=40).synchronize(n)
        return (n, s, make_op("allreduce", **op_kw))

    def measure(ctx, case, nrep):
        n, s, o = ctx
        return run_windowed(n, s, o, case.msize, nrep, 400e-6).valid_times

    recs = run_design(ExperimentDesign(n_launch_epochs=10, nrep=60, seed=seed0),
                      epoch, measure, [TestCase("allreduce", m)
                                       for m in (256, 4096)])
    return analyze_records(recs)

lib_a = campaign(dict(gamma=2e-6), 100)                 # library A
lib_b = campaign(dict(gamma=2e-6, alpha=3.8e-6), 900)   # library B (slower)
print("\nWilcoxon comparison over 10 launch epochs each:")
print(format_comparison(compare_tables(lib_a, lib_b), "libA", "libB"))
