"""Quickstart: the paper's methodology in 60 lines.

Synchronize a (simulated) 16-host cluster with HCA, measure a collective
under window-based sync vs. a skewed library barrier, then compare two
"MPI libraries" the statistically sound way — as two *campaigns* on the
pluggable measurement-backend API, with adaptive nrep and a persistent
result store.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import numpy as np

from repro.campaign import Campaign, CampaignSpec, ResultStore, SimBackend
from repro.core import (
    ExperimentDesign, SimNet, TestCase, compare_tables, format_comparison,
    make_op, make_sync, run_barrier_timed, run_windowed, true_offsets,
)

# --- 1. drift-corrected clock synchronization (HCA, §4.4) -----------------
net = SimNet(16, seed=0)
sync = make_sync("hca", n_fitpts=200, n_exchanges=40).synchronize(net)
print(f"HCA sync: {sync.duration:.3f}s, "
      f"max offset {np.abs(true_offsets(net, sync))[1:].max()*1e6:.2f}us")
net.sleep_all(10.0)
print(f"  after 10s of drift: "
      f"{np.abs(true_offsets(net, sync))[1:].max()*1e6:.2f}us (still synced)")

# --- 2. window-based vs barrier-based measurement (§4.6) -------------------
op = make_op("allreduce")
wr = run_windowed(net, sync, op, msize=8192, nrep=200, win_size=400e-6)
net2 = SimNet(16, seed=0)
br = run_barrier_timed(net2, op, 8192, 200, barrier_exit_skew=40e-6)
print(f"windowed global time : {wr.valid_times.mean()*1e6:8.2f}us "
      f"(invalid {wr.invalid_fraction*100:.1f}%)")
print(f"barrier local-max    : {br.times_local.mean()*1e6:8.2f}us "
      f"(includes ~40us library barrier skew!)")

# --- 3. statistically sound comparison, the campaign way (§6) --------------
# One spec; two backends modeling two "MPI libraries". Adaptive nrep: each
# case keeps sampling until its mean is known to ~3%, capped at 200 reps.
spec = CampaignSpec(
    cases=[TestCase("allreduce", m) for m in (256, 4096)],
    design=ExperimentDesign(n_launch_epochs=10, nrep_min=30, nrep_max=200,
                            rel_ci_target=0.03, seed=42),
    name="quickstart",
)
lib_a = SimBackend(p=8, seed0=100, op_kw=dict(gamma=2e-6))
lib_b = SimBackend(p=8, seed0=900, op_kw=dict(gamma=2e-6, alpha=3.8e-6))

with tempfile.TemporaryDirectory() as td:
    store_a = ResultStore(os.path.join(td, "libA.jsonl"))
    store_b = ResultStore(os.path.join(td, "libB.jsonl"))
    res_a = Campaign(spec, lib_a, store_a).run()
    res_b = Campaign(spec, lib_b, store_b).run()
    used = [r.meta["nrep_used"] for r in res_a.records]
    print(f"\nadaptive nrep: {min(used)}..{max(used)} reps/case "
          f"(cap 200); store holds {len(store_a.records())} cells "
          f"under fingerprint {res_a.fingerprint}")

    # a second run against the same store would resume, not re-measure;
    # compare_tables reads the persisted campaigns directly.
    print("\nWilcoxon comparison over 10 launch epochs each:")
    print(format_comparison(compare_tables(store_a, store_b), "libA", "libB"))
